"""Differential tests: the batched array engines (compiled scan/vmap and
fused Pallas-kernel) vs the interpretive reference simulator.

Each array engine (core/engine.py) must be a *drop-in* for the
reference loop: spikes bit-identical, SOP/flit/energy accounting within
1e-6 relative, across dense and conv-shaped networks, single- and
multi-domain mappings, quantized and fp32 weights, batch 1 and batch 8.
The fused engine is additionally held to a *stronger* contract vs the
compiled engine — bit-exact equality of spikes AND accounting (its
kernel runs the identical float program) — and its ZSPE spike-word skip
telemetry is checked against a numpy popcount oracle.  Engine invariants
(batched == stacked, zero input, placement permutation) are
property-tested via tests/hypothesis_compat.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.quant import CodebookConfig
from repro.core.soc import ChipSimulator, CoreAssignment, Mapping

REL_TOL = 1e-6

STAT_FIELDS = ("nominal_sops", "performed_sops", "spikes_in",
               "spikes_routed", "neurons_touched", "noc_hops",
               "noc_energy_pj", "noc_contention_cycles")
REPORT_FIELDS = ("energy_pj", "core_energy_pj", "noc_energy_pj",
                 "riscv_energy_pj", "wall_cycles")

ENGINES = ("compiled", "fused")


def make_weights(rng, sizes, scale=0.5):
    return [jnp.asarray(rng.normal(0, scale, (sizes[i], sizes[i + 1])),
                        jnp.float32)
            for i in range(len(sizes) - 1)]


def make_trains(rng, batch, timesteps, n_in, density=0.25):
    return jnp.asarray(rng.random((batch, timesteps, n_in)) < density,
                       jnp.float32)


def sim_pair(weights, mapping=None, quant_cfg=None, engine="compiled", **kw):
    """Reference + array-engine simulators sharing one mapping."""
    ref = ChipSimulator(weights, engine="reference", mapping=mapping,
                        quant_cfg=quant_cfg, **kw)
    comp = ChipSimulator(weights, engine=engine, mapping=ref.mapping,
                         quant_cfg=quant_cfg, **kw)
    return ref, comp


def assert_equivalent(ref, comp, trains):
    counts_c, reps_c = comp.run_batch(trains)
    for b in range(int(trains.shape[0])):
        counts_r, rep_r = ref.run_reference(trains[b])
        np.testing.assert_array_equal(
            np.asarray(counts_c[b]), np.asarray(counts_r),
            err_msg=f"sample {b}: compiled spikes differ from reference")
        for f in STAT_FIELDS:
            a = getattr(rep_r.stats, f)
            c = getattr(reps_c[b].stats, f)
            assert abs(a - c) <= REL_TOL * max(abs(a), 1.0), (b, f, a, c)
        for f in REPORT_FIELDS:
            a = getattr(rep_r, f)
            c = getattr(reps_c[b], f)
            assert abs(a - c) <= REL_TOL * max(abs(a), 1.0), (b, f, a, c)


def conv_shaped_sizes():
    """im2col'd layer sizes of a small spiking conv net."""
    from repro import compiler as COMP
    from repro.models.snn_conv import ConvSNNConfig

    cfg = ConvSNNConfig(in_shape=(8, 8, 2), channels=(4, 8), n_classes=10)
    return COMP.from_conv_config(cfg).layer_sizes()


def multi_domain_mapping(sizes):
    """Force a >20-core mapping so it spans two level-1 domains."""
    from repro import compiler as COMP

    spec = COMP.ChipSpec(neurons_per_core=8, max_domains=2)
    compiled = COMP.compile_network(list(sizes), spec)
    mapping = compiled.to_soc_mapping()
    assert compiled.n_domains_used >= 2, "case must exercise scale-up"
    return mapping


# ---------------------------------------------------------------------------
# randomized differential cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("batch", [1, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dense_fp32_matches_reference(seed, batch, engine):
    rng = np.random.default_rng(seed)
    n_hidden = int(rng.integers(32, 128))
    sizes = (int(rng.integers(16, 64)), n_hidden, 10)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, mapping_strategy="greedy", engine=engine)
    assert_equivalent(ref, comp, make_trains(rng, batch, 10, sizes[0]))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("batch", [1, 8])
def test_dense_quantized_matches_reference(batch, engine):
    rng = np.random.default_rng(7)
    sizes = (48, 96, 32, 10)
    w = make_weights(rng, sizes, scale=0.1)
    ref, comp = sim_pair(w, quant_cfg=CodebookConfig(n_levels=16, bit_width=8),
                         engine=engine)
    if engine == "fused":
        # the registers are programmed -> every layer must run compressed
        fe = comp.fused_engine()
        assert fe.codebook_layers == len(w)
    assert_equivalent(ref, comp, make_trains(rng, batch, 12, sizes[0]))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("batch", [1, 8])
def test_conv_shaped_matches_reference(batch, engine):
    rng = np.random.default_rng(11)
    sizes = conv_shaped_sizes()
    w = make_weights(rng, sizes, scale=0.15)
    ref, comp = sim_pair(w, engine=engine)
    assert_equivalent(ref, comp, make_trains(rng, batch, 6, sizes[0],
                                             density=0.15))


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("batch", [1, 8])
def test_multi_domain_matches_reference(batch, engine):
    rng = np.random.default_rng(23)
    sizes = (16, 128, 64)
    mapping = multi_domain_mapping(sizes)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, mapping=mapping, engine=engine)
    assert ref.interconnect is not None        # level-2 pricing active
    assert_equivalent(ref, comp, make_trains(rng, batch, 8, sizes[0],
                                             density=0.3))


@pytest.mark.parametrize("engine", ENGINES)
def test_baseline_scheme_matches_reference(engine):
    """No zero-skip / full MP update (the paper's 'traditional' baseline)."""
    rng = np.random.default_rng(3)
    sizes = (32, 64, 10)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, zero_skip=False, partial_update=False,
                         engine=engine)
    assert_equivalent(ref, comp, make_trains(rng, 2, 8, sizes[0]))


def test_run_dispatches_by_engine():
    rng = np.random.default_rng(4)
    w = make_weights(rng, (24, 32, 10))
    train = make_trains(rng, 1, 6, 24)[0]
    ref, comp = sim_pair(w)
    counts_r, rep_r = ref.run(train)           # reference path via run()
    for engine in ENGINES:
        sim = ChipSimulator(w, engine=engine, mapping=ref.mapping)
        counts_c, rep_c = sim.run(train)       # array single-sample path
        np.testing.assert_array_equal(np.asarray(counts_c),
                                      np.asarray(counts_r))
        assert (abs(rep_c.energy_pj - rep_r.energy_pj)
                <= REL_TOL * rep_r.energy_pj)
    with pytest.raises(ValueError):
        ChipSimulator(w, engine="warp-drive")


# ---------------------------------------------------------------------------
# engine invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), batch=st.integers(2, 5))
def test_batched_equals_stacked_per_sample(seed, batch):
    """vmap over a batch == the same samples run one at a time."""
    rng = np.random.default_rng(seed)
    sizes = (24, 48, 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    trains = make_trains(rng, batch, 8, sizes[0])
    counts_b, reps_b = sim.run_batch(trains)
    for b in range(batch):
        counts_1, rep_1 = sim.run(trains[b])
        np.testing.assert_array_equal(np.asarray(counts_b[b]),
                                      np.asarray(counts_1))
        assert reps_b[b].energy_pj == rep_1.energy_pj
        assert reps_b[b].stats.performed_sops == rep_1.stats.performed_sops
        assert reps_b[b].wall_cycles == rep_1.wall_cycles


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_zero_input_leak_only(seed):
    """All-zero spike trains: no SOPs performed, no flits routed, energy
    is leak/pipeline-only (core at sparsity 1 + RISC-V), never zero."""
    rng = np.random.default_rng(seed)
    sizes = (16, int(rng.integers(24, 64)), 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    counts, reps = sim.run_batch(jnp.zeros((2, 6, sizes[0]), jnp.float32))
    assert float(jnp.abs(counts).max()) == 0.0
    for rep in reps:
        assert rep.stats.performed_sops == 0.0
        assert rep.stats.spikes_in == 0.0
        assert rep.stats.noc_hops == 0.0
        assert rep.stats.spikes_routed == 0.0
        assert rep.noc_energy_pj == 0.0
        assert rep.stats.sparsity == 1.0
        assert rep.energy_pj > 0.0
        np.testing.assert_allclose(
            rep.energy_pj, rep.core_energy_pj + rep.riscv_energy_pj,
            rtol=1e-12)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_total_sops_permutation_invariant(seed):
    """Total SOPs depend on the network + spikes, not on which physical
    core each slice landed on."""
    rng = np.random.default_rng(seed)
    sizes = (24, 96, 10)
    w = make_weights(rng, sizes)
    base = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    active = base.mapping.active_core_ids()
    perm = dict(zip(active, rng.permutation(active)))
    permuted = Mapping(
        assignments=[CoreAssignment(core_id=int(perm[a.core_id]),
                                    layer=a.layer, neuron_lo=a.neuron_lo,
                                    neuron_hi=a.neuron_hi)
                     for a in base.mapping.assignments],
        layer_sizes=list(base.mapping.layer_sizes))
    shuf = ChipSimulator(w, engine="compiled", mapping=permuted)
    trains = make_trains(rng, 2, 6, sizes[0])
    _, reps_a = base.run_batch(trains)
    _, reps_b = shuf.run_batch(trains)
    for ra, rb in zip(reps_a, reps_b):
        assert ra.stats.nominal_sops == rb.stats.nominal_sops
        assert ra.stats.performed_sops == rb.stats.performed_sops
        assert ra.stats.neurons_touched == rb.stats.neurons_touched


# ---------------------------------------------------------------------------
# fused engine: stronger contracts than the compiled/reference pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True])
def test_fused_bitexact_vs_compiled(quant):
    """Fused vs compiled is not a tolerance comparison: with word-aligned
    layer widths (every n_pre a multiple of 16, so spike packing adds no
    K padding) the fused kernel runs the identical float program, and
    spikes AND every accounting field must be exactly equal."""
    rng = np.random.default_rng(17)
    sizes = (48, 80, 32, 10)
    w = make_weights(rng, sizes, scale=0.2)
    qcfg = CodebookConfig(n_levels=16, bit_width=8) if quant else None
    comp = ChipSimulator(w, engine="compiled", quant_cfg=qcfg)
    fus = ChipSimulator(w, engine="fused", mapping=comp.mapping,
                        quant_cfg=qcfg)
    trains = make_trains(rng, 4, 10, sizes[0])
    counts_c, reps_c = comp.run_batch(trains)
    counts_f, reps_f = fus.run_batch(trains)
    np.testing.assert_array_equal(np.asarray(counts_f), np.asarray(counts_c))
    for rc, rf in zip(reps_c, reps_f):
        for f in STAT_FIELDS:
            assert getattr(rf.stats, f) == getattr(rc.stats, f), f
        for f in REPORT_FIELDS:
            assert getattr(rf, f) == getattr(rc, f), f


def test_fused_skip_words_match_popcount_oracle():
    """The fused engine's ZSPE skip telemetry == an exact numpy popcount:
    for every (sample, step), the number of all-zero 16-spike words in
    the layer's input."""
    from repro.core.zspe import SPIKE_WORD_BITS

    rng = np.random.default_rng(29)
    n_in, n_out = 70, 12                        # 70 spikes -> 5 words/step
    w = make_weights(rng, (n_in, n_out))
    sim = ChipSimulator(w, engine="fused", mapping_strategy="greedy")
    trains = make_trains(rng, 3, 9, n_in, density=0.05)
    ys = sim.fused_engine().run_raw(trains)
    skip = np.asarray(ys["skip_words"])         # (B, T, L=1)
    assert skip.shape == (3, 9, 1)

    t_np = np.asarray(trains)                   # exact word-level oracle
    n_words = -(-n_in // SPIKE_WORD_BITS)
    padded = np.zeros((3, 9, n_words * SPIKE_WORD_BITS), np.float32)
    padded[:, :, :n_in] = t_np
    words = padded.reshape(3, 9, n_words, SPIKE_WORD_BITS)
    expected = (words.sum(-1) == 0).sum(-1)     # empty words per (b, t)
    assert expected.sum() > 0, "case must exercise the word-skip path"
    assert expected.sum() < 3 * 9 * n_words, "case must also do work"
    np.testing.assert_array_equal(skip[:, :, 0], expected)

    # the per-report aggregate is the plain sum of the telemetry
    _, reps = sim.run_batch(trains)
    for b, rep in enumerate(reps):
        assert rep.stats.spike_words_skipped == expected[b].sum()


def test_fused_per_core_register_tables_run_compressed():
    """Deploy-style per-core PTQ: every layer must lower to codebook mode
    (RegisterTable words consumed in-register) and match the reference."""
    from repro.core.soc import map_network
    from repro.deploy import fit_per_core_codebooks
    from repro.models import snn as SNN
    from repro.models.snn import SNNConfig

    cfg = SNNConfig(layer_sizes=(64, 48, 10), timesteps=6)
    params = SNN.init_params(cfg, jax.random.PRNGKey(0))
    mapping = map_network(list(cfg.layer_sizes), strategy="anneal")
    pq = fit_per_core_codebooks(params, mapping, CodebookConfig(16, 8))

    ref = ChipSimulator(pq.weights, engine="reference", mapping=mapping,
                        register_tables=pq.tables)
    fus = ChipSimulator(pq.weights, engine="fused", mapping=mapping,
                        register_tables=pq.tables)
    fe = fus.fused_engine()
    assert fe.codebook_layers == len(pq.weights)
    # codebook operands are int8 indexes: materially fewer weight HBM
    # bytes even at this toy size (the asymptotic >= 4x — f32 vs int8,
    # level table amortized over large K — is asserted at NMNIST scale
    # by benchmarks/engine_bench.py)
    dense_bytes = sum(lw.n_pre * lw.n_post * 4 for lw in fe.fused_weights)
    fused_w_bytes = sum(
        lw.idx.size * 1 + lw.cbw.size * 4 for lw in fe.fused_weights)
    assert dense_bytes / fused_w_bytes >= 1.9
    rng = np.random.default_rng(5)
    assert_equivalent(ref, fus, make_trains(rng, 4, 6, 64, density=0.2))


def test_fused_shard_map_multi_device():
    """With >= 2 devices and a divisible batch the fused engine runs the
    program through shard_map and still matches the reference exactly."""
    if len(jax.devices()) < 2:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=2")
    rng = np.random.default_rng(31)
    sizes = (48, 64, 10)
    w = make_weights(rng, sizes)
    ref = ChipSimulator(w, engine="reference")
    fus = ChipSimulator(w, engine="fused", mapping=ref.mapping)
    trains = make_trains(rng, 4, 8, sizes[0])
    counts, reps = fus.run_batch(trains)
    assert fus.fused_engine().last_run_sharded
    for b in range(4):
        counts_r, rep_r = ref.run_reference(trains[b])
        np.testing.assert_array_equal(np.asarray(counts[b]),
                                      np.asarray(counts_r))
        assert (abs(reps[b].energy_pj - rep_r.energy_pj)
                <= REL_TOL * rep_r.energy_pj)
    # a batch that does not divide the device count falls back cleanly
    counts3, _ = fus.run_batch(trains[:3])
    assert not fus.fused_engine().last_run_sharded
    np.testing.assert_array_equal(np.asarray(counts3),
                                  np.asarray(counts[:3]))


def test_fused_engine_block_selection():
    """Interpret mode runs one exact tile (the bit-exact config); the
    real-TPU path tiles to divisors that cap the VMEM weight slab."""
    from repro.core.engine import _pick_engine_block

    assert _pick_engine_block(32, 2320, 512, interpret=True) is None
    bm, bn = _pick_engine_block(32, 8192, 8192, interpret=False)
    assert 32 % bm == 0 and 8192 % bn == 0
    assert bm <= 8 and 8192 * bn <= 1 << 20        # <= 4 MB f32 slab
    bm, bn = _pick_engine_block(3, 16, 509, interpret=False)   # prime N
    assert bm in (1, 3) and 509 % bn == 0


def test_fused_rejects_soft_reset():
    from repro.core.neuron import LIFParams

    rng = np.random.default_rng(2)
    w = make_weights(rng, (16, 8))
    sim = ChipSimulator(w, engine="fused", lif=LIFParams(reset_mode="soft"))
    with pytest.raises(ValueError, match="hard reset"):
        sim.fused_engine()


# ---------------------------------------------------------------------------
# source-exact NoC accounting (PR 5 tentpole)
# ---------------------------------------------------------------------------

def test_noc_accounting_is_source_exact():
    """Two firing patterns with EQUAL total fired spikes but different
    source cores must price differently — the uniform-split heuristic
    could not tell them apart.  All three engines must agree per pattern.
    The probe network is shared with benchmarks/contention_bench.py via
    repro.core.probes."""
    from repro.core.probes import source_exact_patterns, source_exact_probe

    sim_c, srcs, dst = source_exact_probe("compiled")
    sim_r, *_ = source_exact_probe("reference")
    sim_f, *_ = source_exact_probe("fused")
    near_tr, far_tr, (near_hops, far_hops) = source_exact_patterns(
        sim_c, srcs, dst)
    assert near_hops != far_hops
    reports = []
    for tr in (near_tr, far_tr):
        assert_equivalent(sim_r, sim_c, tr)   # reference vs compiled
        assert_equivalent(sim_r, sim_f, tr)   # reference vs fused
        _, [rep_c] = sim_c.run_batch(tr)
        reports.append(rep_c)
    assert reports[0].stats.spikes_routed == reports[1].stats.spikes_routed
    # ...but the near-core pattern is strictly cheaper on the NoC
    assert reports[0].stats.noc_energy_pj < reports[1].stats.noc_energy_pj
    assert reports[0].stats.noc_hops < reports[1].stats.noc_hops


@pytest.mark.parametrize("engine", ENGINES + ("reference",))
def test_zero_spike_batches_are_finite(engine):
    """All-padding (zero) batches through run_batch on every engine: all
    counters zero, and no NaN/inf anywhere in the derived report fields."""
    rng = np.random.default_rng(41)
    w = make_weights(rng, (32, 48, 10))
    sim = ChipSimulator(w, engine=engine, mapping_strategy="greedy")
    counts, reps = sim.run_batch(jnp.zeros((3, 5, 32), jnp.float32))
    assert float(jnp.abs(counts).max()) == 0.0
    for rep in reps:
        s = rep.stats
        assert s.performed_sops == 0.0 and s.spikes_in == 0.0
        assert s.spikes_routed == 0.0 and s.noc_hops == 0.0
        assert s.noc_energy_pj == 0.0 and s.noc_contention_cycles == 0.0
        for val in (rep.pj_per_sop, rep.power_mw, s.sparsity,
                    rep.energy_pj, rep.wall_cycles, rep.gsops):
            assert np.isfinite(val), (engine, val)
        assert s.sparsity == 1.0


def test_step_stats_sparsity_zero_nominal():
    """A default-constructed (or zero-input) StepStats reports sparsity
    1.0 instead of raising ZeroDivisionError — same convention as
    energy.price_batched."""
    from repro.core.soc import StepStats

    assert StepStats().sparsity == 1.0
    assert StepStats(nominal_sops=0.0, performed_sops=0.0).sparsity == 1.0
    assert StepStats(nominal_sops=10.0, performed_sops=5.0).sparsity == 0.5


# ---------------------------------------------------------------------------
# array-native NoC replay agrees with the interpretive replay
# ---------------------------------------------------------------------------

def test_flow_table_matches_replay_flows():
    """`compile_flow_table` + `replay_flows_array` == `replay_flows` for
    uniform per-flow spike counts (hops, energy, cycles), with and
    without the level-2 interconnect pricing."""
    from repro.core import energy as E
    from repro.core import noc as NOC

    rng = np.random.default_rng(5)
    rt = NOC.RoutingTable(NOC.fullerene_adjacency())
    flows = NOC.uniform_random_flows(rng, 40, bcast_frac=0.4)
    routes = [NOC.compile_flow(rt, src, dsts) for src, dsts, _ in flows]
    params = NOC.RouterParams()
    for interconnect in (None, E.InterconnectEnergyModel.from_router(params)):
        for n_spikes in (1, 7):
            ref = NOC.replay_flows([(r, n_spikes) for r in routes], params,
                                   interconnect=interconnect)
            table = NOC.compile_flow_table(routes, params,
                                           interconnect=interconnect)
            hops, energy, cycles = NOC.replay_flows_array(
                table, n_spikes, params)
            assert hops == ref.total_hops
            np.testing.assert_allclose(energy, ref.energy_pj, rtol=1e-12)
            np.testing.assert_allclose(cycles, ref.cycles, rtol=1e-12)
            assert int(table.dst_fanout.sum()) * n_spikes == ref.spikes_delivered


def test_replay_flows_exact_matches_replay_flows():
    """Per-flow exact replay (the engines' path) == the interpretive
    `replay_flows` on identical per-flow spike counts, including the
    router-load vector that feeds the contention model."""
    from repro.core import energy as E
    from repro.core import noc as NOC

    rng = np.random.default_rng(9)
    rt = NOC.RoutingTable(NOC.fullerene_adjacency())
    flows = NOC.uniform_random_flows(rng, 30, bcast_frac=0.3)
    routes = [NOC.compile_flow(rt, src, dsts) for src, dsts, _ in flows]
    counts = rng.integers(0, 12, size=len(routes))
    params = NOC.RouterParams()
    for interconnect in (None, E.InterconnectEnergyModel.from_router(params)):
        table = NOC.compile_flow_table(routes, params,
                                       interconnect=interconnect)
        np.testing.assert_array_equal(
            table.src_core, [r.src for r in routes])
        ref = NOC.replay_flows(
            [(r, int(c)) for r, c in zip(routes, counts)], params,
            interconnect=interconnect)
        hops, energy, load = NOC.replay_flows_exact(table, counts)
        assert hops == ref.total_hops
        np.testing.assert_allclose(energy, ref.energy_pj, rtol=1e-12)
        np.testing.assert_array_equal(load, ref.router_load)
        # batched leading axes broadcast through
        h2, e2, l2 = NOC.replay_flows_exact(
            table, np.stack([counts, 2 * counts]))
        assert h2.shape == (2,) and l2.shape == (2, NOC.N_NODES)
        np.testing.assert_allclose(h2[0], hops)
        np.testing.assert_allclose(e2[1], 2 * energy, rtol=1e-12)


def test_contention_cycles_model():
    """Zero spikes cost zero; light load approaches pure serialization;
    the term grows superlinearly with the bottleneck load."""
    from repro.core import noc as NOC

    p = NOC.RouterParams()
    assert float(NOC.contention_cycles(0.0, 100.0, p)) == 0.0
    light = float(NOC.contention_cycles(1.0, 1e6, p))
    np.testing.assert_allclose(light, 1.0 / p.peak_throughput, rtol=1e-3)
    c1 = float(NOC.contention_cycles(100.0, 50.0, p))
    c2 = float(NOC.contention_cycles(200.0, 50.0, p))
    assert c2 > 2 * c1                       # superlinear in load
    arr = NOC.contention_cycles(np.array([[0.0, 10.0], [20.0, 40.0]]),
                                np.full((2, 2), 64.0), p)
    assert arr.shape == (2, 2) and arr[0, 0] == 0.0
    assert np.all(np.diff(arr.ravel()) > 0)


def test_fullerene_saturates_after_mesh():
    """Acceptance: the fullerene fabric sustains a higher injection rate
    before bottleneck-router saturation than the 4x8 mesh (and the mesh
    beats the tree)."""
    from repro.core import noc as NOC

    full = NOC.saturation_injection_rate(NOC.fullerene_adjacency(),
                                         NOC.core_ids())
    mesh = NOC.saturation_injection_rate(NOC.mesh_2d(4, 8), np.arange(32))
    tree = NOC.saturation_injection_rate(NOC.tree(32, 2), np.arange(32))
    assert full > mesh > tree


# ---------------------------------------------------------------------------
# serving path rides the batched engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_snn_server_batches_requests(engine):
    from repro.serve.snn_server import SnnRequest, SnnServer

    rng = np.random.default_rng(0)
    sizes = (32, 64, 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine=engine, mapping_strategy="greedy")
    srv = SnnServer(sim, batch_slots=4)
    events = [np.asarray(rng.random((8, 32)) < 0.3, np.float32)
              for _ in range(6)]
    for uid, ev in enumerate(events):
        srv.submit(SnnRequest(uid=uid, events=ev))
    done = srv.run()
    assert len(done) == 6
    for r in done:
        assert 0 <= r.prediction < 10
        assert r.energy_pj > 0
        # per-request telemetry matches a direct single-sample run
        counts, rep = sim.run(jnp.asarray(r.events))
        assert int(np.argmax(np.asarray(counts))) == r.prediction
        np.testing.assert_allclose(r.energy_pj, rep.energy_pj, rtol=1e-12)

    with pytest.raises(ValueError):
        SnnServer(ChipSimulator(w, engine="reference"), batch_slots=2)


def test_snn_server_partial_group_no_padded_telemetry():
    """A partial group (fewer requests than batch_slots) pads the batch
    with all-zero trains; the padded slots' telemetry must never reach a
    real request, and the queue must drain per group in one pass."""
    from repro.serve.snn_server import SnnRequest, SnnServer

    rng = np.random.default_rng(3)
    sizes = (24, 40, 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    srv = SnnServer(sim, batch_slots=4)
    events = [np.asarray(rng.random((7, 24)) < 0.4, np.float32)
              for _ in range(5)]                      # group of 4 + 1 partial
    for uid, ev in enumerate(events):
        srv.submit(SnnRequest(uid=uid, events=ev))
    done = srv.run()
    assert len(done) == 5 and srv.queue == []
    # what a padded (all-zero) slot would report
    _, [pad_rep] = sim.run_batch(jnp.zeros((1, 7, 24), jnp.float32))
    for r in done:
        counts, rep = sim.run(jnp.asarray(r.events))  # ground truth per uid
        np.testing.assert_allclose(r.energy_pj, rep.energy_pj, rtol=1e-12)
        np.testing.assert_allclose(r.pj_per_sop, rep.pj_per_sop, rtol=1e-12)
        assert r.prediction == int(np.argmax(np.asarray(counts)))
        # real requests fire spikes here; a padded-slot leak would hand
        # them the zero-input report instead
        assert r.energy_pj != pad_rep.energy_pj
