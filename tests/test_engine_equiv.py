"""Differential tests: the batched XLA-compiled engine vs the
interpretive reference simulator.

The compiled engine (core/engine.py) must be a *drop-in* for the
reference loop: spikes bit-identical, SOP/flit/energy accounting within
1e-6 relative, across dense and conv-shaped networks, single- and
multi-domain mappings, quantized and fp32 weights, batch 1 and batch 8.
Engine invariants (batched == stacked, zero input, placement
permutation) are property-tested via tests/hypothesis_compat.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.quant import CodebookConfig
from repro.core.soc import ChipSimulator, CoreAssignment, Mapping

REL_TOL = 1e-6

STAT_FIELDS = ("nominal_sops", "performed_sops", "spikes_in",
               "spikes_routed", "neurons_touched", "noc_hops",
               "noc_energy_pj")
REPORT_FIELDS = ("energy_pj", "core_energy_pj", "noc_energy_pj",
                 "riscv_energy_pj", "wall_cycles")


def make_weights(rng, sizes, scale=0.5):
    return [jnp.asarray(rng.normal(0, scale, (sizes[i], sizes[i + 1])),
                        jnp.float32)
            for i in range(len(sizes) - 1)]


def make_trains(rng, batch, timesteps, n_in, density=0.25):
    return jnp.asarray(rng.random((batch, timesteps, n_in)) < density,
                       jnp.float32)


def sim_pair(weights, mapping=None, quant_cfg=None, **kw):
    """Reference + compiled simulators sharing one mapping."""
    ref = ChipSimulator(weights, engine="reference", mapping=mapping,
                        quant_cfg=quant_cfg, **kw)
    comp = ChipSimulator(weights, engine="compiled", mapping=ref.mapping,
                         quant_cfg=quant_cfg, **kw)
    return ref, comp


def assert_equivalent(ref, comp, trains):
    counts_c, reps_c = comp.run_batch(trains)
    for b in range(int(trains.shape[0])):
        counts_r, rep_r = ref.run_reference(trains[b])
        np.testing.assert_array_equal(
            np.asarray(counts_c[b]), np.asarray(counts_r),
            err_msg=f"sample {b}: compiled spikes differ from reference")
        for f in STAT_FIELDS:
            a = getattr(rep_r.stats, f)
            c = getattr(reps_c[b].stats, f)
            assert abs(a - c) <= REL_TOL * max(abs(a), 1.0), (b, f, a, c)
        for f in REPORT_FIELDS:
            a = getattr(rep_r, f)
            c = getattr(reps_c[b], f)
            assert abs(a - c) <= REL_TOL * max(abs(a), 1.0), (b, f, a, c)


def conv_shaped_sizes():
    """im2col'd layer sizes of a small spiking conv net."""
    from repro import compiler as COMP
    from repro.models.snn_conv import ConvSNNConfig

    cfg = ConvSNNConfig(in_shape=(8, 8, 2), channels=(4, 8), n_classes=10)
    return COMP.from_conv_config(cfg).layer_sizes()


def multi_domain_mapping(sizes):
    """Force a >20-core mapping so it spans two level-1 domains."""
    from repro import compiler as COMP

    spec = COMP.ChipSpec(neurons_per_core=8, max_domains=2)
    compiled = COMP.compile_network(list(sizes), spec)
    mapping = compiled.to_soc_mapping()
    assert compiled.n_domains_used >= 2, "case must exercise scale-up"
    return mapping


# ---------------------------------------------------------------------------
# randomized differential cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 8])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dense_fp32_matches_reference(seed, batch):
    rng = np.random.default_rng(seed)
    n_hidden = int(rng.integers(32, 128))
    sizes = (int(rng.integers(16, 64)), n_hidden, 10)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, mapping_strategy="greedy")
    assert_equivalent(ref, comp, make_trains(rng, batch, 10, sizes[0]))


@pytest.mark.parametrize("batch", [1, 8])
def test_dense_quantized_matches_reference(batch):
    rng = np.random.default_rng(7)
    sizes = (48, 96, 32, 10)
    w = make_weights(rng, sizes, scale=0.1)
    ref, comp = sim_pair(w, quant_cfg=CodebookConfig(n_levels=16, bit_width=8))
    assert_equivalent(ref, comp, make_trains(rng, batch, 12, sizes[0]))


@pytest.mark.parametrize("batch", [1, 8])
def test_conv_shaped_matches_reference(batch):
    rng = np.random.default_rng(11)
    sizes = conv_shaped_sizes()
    w = make_weights(rng, sizes, scale=0.15)
    ref, comp = sim_pair(w)
    assert_equivalent(ref, comp, make_trains(rng, batch, 6, sizes[0],
                                             density=0.15))


@pytest.mark.parametrize("batch", [1, 8])
def test_multi_domain_matches_reference(batch):
    rng = np.random.default_rng(23)
    sizes = (16, 128, 64)
    mapping = multi_domain_mapping(sizes)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, mapping=mapping)
    assert ref.interconnect is not None        # level-2 pricing active
    assert_equivalent(ref, comp, make_trains(rng, batch, 8, sizes[0],
                                             density=0.3))


def test_baseline_scheme_matches_reference():
    """No zero-skip / full MP update (the paper's 'traditional' baseline)."""
    rng = np.random.default_rng(3)
    sizes = (32, 64, 10)
    w = make_weights(rng, sizes)
    ref, comp = sim_pair(w, zero_skip=False, partial_update=False)
    assert_equivalent(ref, comp, make_trains(rng, 2, 8, sizes[0]))


def test_run_dispatches_by_engine():
    rng = np.random.default_rng(4)
    w = make_weights(rng, (24, 32, 10))
    train = make_trains(rng, 1, 6, 24)[0]
    ref, comp = sim_pair(w)
    counts_c, rep_c = comp.run(train)          # compiled single-sample path
    counts_r, rep_r = ref.run(train)           # reference path via run()
    np.testing.assert_array_equal(np.asarray(counts_c), np.asarray(counts_r))
    assert abs(rep_c.energy_pj - rep_r.energy_pj) <= REL_TOL * rep_r.energy_pj
    with pytest.raises(ValueError):
        ChipSimulator(w, engine="warp-drive")


# ---------------------------------------------------------------------------
# engine invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), batch=st.integers(2, 5))
def test_batched_equals_stacked_per_sample(seed, batch):
    """vmap over a batch == the same samples run one at a time."""
    rng = np.random.default_rng(seed)
    sizes = (24, 48, 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    trains = make_trains(rng, batch, 8, sizes[0])
    counts_b, reps_b = sim.run_batch(trains)
    for b in range(batch):
        counts_1, rep_1 = sim.run(trains[b])
        np.testing.assert_array_equal(np.asarray(counts_b[b]),
                                      np.asarray(counts_1))
        assert reps_b[b].energy_pj == rep_1.energy_pj
        assert reps_b[b].stats.performed_sops == rep_1.stats.performed_sops
        assert reps_b[b].wall_cycles == rep_1.wall_cycles


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_zero_input_leak_only(seed):
    """All-zero spike trains: no SOPs performed, no flits routed, energy
    is leak/pipeline-only (core at sparsity 1 + RISC-V), never zero."""
    rng = np.random.default_rng(seed)
    sizes = (16, int(rng.integers(24, 64)), 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    counts, reps = sim.run_batch(jnp.zeros((2, 6, sizes[0]), jnp.float32))
    assert float(jnp.abs(counts).max()) == 0.0
    for rep in reps:
        assert rep.stats.performed_sops == 0.0
        assert rep.stats.spikes_in == 0.0
        assert rep.stats.noc_hops == 0.0
        assert rep.stats.spikes_routed == 0.0
        assert rep.noc_energy_pj == 0.0
        assert rep.stats.sparsity == 1.0
        assert rep.energy_pj > 0.0
        np.testing.assert_allclose(
            rep.energy_pj, rep.core_energy_pj + rep.riscv_energy_pj,
            rtol=1e-12)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_total_sops_permutation_invariant(seed):
    """Total SOPs depend on the network + spikes, not on which physical
    core each slice landed on."""
    rng = np.random.default_rng(seed)
    sizes = (24, 96, 10)
    w = make_weights(rng, sizes)
    base = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    active = base.mapping.active_core_ids()
    perm = dict(zip(active, rng.permutation(active)))
    permuted = Mapping(
        assignments=[CoreAssignment(core_id=int(perm[a.core_id]),
                                    layer=a.layer, neuron_lo=a.neuron_lo,
                                    neuron_hi=a.neuron_hi)
                     for a in base.mapping.assignments],
        layer_sizes=list(base.mapping.layer_sizes))
    shuf = ChipSimulator(w, engine="compiled", mapping=permuted)
    trains = make_trains(rng, 2, 6, sizes[0])
    _, reps_a = base.run_batch(trains)
    _, reps_b = shuf.run_batch(trains)
    for ra, rb in zip(reps_a, reps_b):
        assert ra.stats.nominal_sops == rb.stats.nominal_sops
        assert ra.stats.performed_sops == rb.stats.performed_sops
        assert ra.stats.neurons_touched == rb.stats.neurons_touched


# ---------------------------------------------------------------------------
# array-native NoC replay agrees with the interpretive replay
# ---------------------------------------------------------------------------

def test_flow_table_matches_replay_flows():
    """`compile_flow_table` + `replay_flows_array` == `replay_flows` for
    uniform per-flow spike counts (hops, energy, cycles), with and
    without the level-2 interconnect pricing."""
    from repro.core import energy as E
    from repro.core import noc as NOC

    rng = np.random.default_rng(5)
    rt = NOC.RoutingTable(NOC.fullerene_adjacency())
    flows = NOC.uniform_random_flows(rng, 40, bcast_frac=0.4)
    routes = [NOC.compile_flow(rt, src, dsts) for src, dsts, _ in flows]
    params = NOC.RouterParams()
    for interconnect in (None, E.InterconnectEnergyModel.from_router(params)):
        for n_spikes in (1, 7):
            ref = NOC.replay_flows([(r, n_spikes) for r in routes], params,
                                   interconnect=interconnect)
            table = NOC.compile_flow_table(routes, params,
                                           interconnect=interconnect)
            hops, energy, cycles = NOC.replay_flows_array(
                table, n_spikes, params)
            assert hops == ref.total_hops
            np.testing.assert_allclose(energy, ref.energy_pj, rtol=1e-12)
            np.testing.assert_allclose(cycles, ref.cycles, rtol=1e-12)
            assert int(table.dst_fanout.sum()) * n_spikes == ref.spikes_delivered


# ---------------------------------------------------------------------------
# serving path rides the batched engine
# ---------------------------------------------------------------------------

def test_snn_server_batches_requests():
    from repro.serve.snn_server import SnnRequest, SnnServer

    rng = np.random.default_rng(0)
    sizes = (32, 64, 10)
    w = make_weights(rng, sizes)
    sim = ChipSimulator(w, engine="compiled", mapping_strategy="greedy")
    srv = SnnServer(sim, batch_slots=4)
    events = [np.asarray(rng.random((8, 32)) < 0.3, np.float32)
              for _ in range(6)]
    for uid, ev in enumerate(events):
        srv.submit(SnnRequest(uid=uid, events=ev))
    done = srv.run()
    assert len(done) == 6
    for r in done:
        assert 0 <= r.prediction < 10
        assert r.energy_pj > 0
        # per-request telemetry matches a direct single-sample run
        counts, rep = sim.run(jnp.asarray(r.events))
        assert int(np.argmax(np.asarray(counts))) == r.prediction
        np.testing.assert_allclose(r.energy_pj, rep.energy_pj, rtol=1e-12)

    with pytest.raises(ValueError):
        SnnServer(ChipSimulator(w, engine="reference"), batch_slots=2)
