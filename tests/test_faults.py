"""Fault-injection subsystem: differential engine parity under faults,
the zero-cost-off jaxpr claim, seeded sampling determinism, codebook
corruption, fault-aware compiler repair, and survivability sanity.

These pin the PR-9 contracts:
* one FaultConfig + seed => bit-identical spikes across the reference
  oracle and both array engines (the fault model lowers to static state
  + a shared DropPlan, never to per-engine control flow);
* a fault-free config is provably free — the compiled engine lowers to
  the SAME jaxpr with and without it;
* `compiler.repair` reroutes on the fault-masked graph while reusing
  every unaffected per-domain placement from the PR-8 cache, and a
  repaired network never routes through a killed router;
* dead cores remap onto spare capacity, loudly failing when none exists.
"""
import re

import jax
import numpy as np
import pytest

from repro import compiler as COMP
from repro.compiler.ir import from_layer_sizes
from repro.core import noc as NOC
from repro.core.soc import ChipSimulator
from repro.faults import (CodebookFault, FaultConfig, NULL_FAULTS,
                          TransientChipFault, masked_adjacency,
                          sample_faults, survivability_study)

SIZES = [64, 96, 96, 16]          # widths stay multiples of 16 (fused pack)
FAULTS = FaultConfig(dead_cores=(14,), failed_routers=(3,),
                     drop_p=0.15, seed=7)


def _weights(sizes=SIZES, seed=0):
    rng = np.random.default_rng(seed)
    return [np.asarray(rng.normal(0, 1.2 / np.sqrt(a), (a, b)), np.float32)
            for a, b in zip(sizes[:-1], sizes[1:])]


def _trains(sizes=SIZES, batch=4, T=6, seed=1):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.random((batch, T, sizes[0])) < 0.25, np.float32)


def _sim(engine, faults=None, sizes=SIZES, seed=0):
    return ChipSimulator(_weights(sizes, seed), engine=engine, faults=faults)


# ---------------------------------------------------------------------------
# differential parity: same faults, same spikes, every engine


def test_engines_bit_identical_under_faults():
    trains = _trains()
    counts, reports = {}, {}
    for eng in ("reference", "compiled", "fused"):
        c, r = _sim(eng, FAULTS).run_batch(trains)
        counts[eng], reports[eng] = np.asarray(c), r
    assert np.array_equal(counts["reference"], counts["compiled"])
    assert np.array_equal(counts["reference"], counts["fused"])
    for eng in ("compiled", "fused"):
        for a, b in zip(reports["reference"], reports[eng]):
            rel = abs(a.energy_pj - b.energy_pj) / max(abs(a.energy_pj), 1.0)
            assert rel <= 1e-6


def test_fault_config_is_deterministic_across_instances():
    trains = _trains()
    c1, _ = _sim("compiled", FAULTS).run_batch(trains)
    c2, _ = _sim("compiled", FaultConfig(dead_cores=(14,),
                                         failed_routers=(3,),
                                         drop_p=0.15, seed=7)
                 ).run_batch(trains)
    assert np.array_equal(np.asarray(c1), np.asarray(c2))


def test_faults_actually_change_the_output():
    trains = _trains()
    clean, _ = _sim("compiled").run_batch(trains)
    faulty, _ = _sim("compiled", FAULTS).run_batch(trains)
    assert not np.array_equal(np.asarray(clean), np.asarray(faulty))


def test_drop_seed_changes_the_loss_pattern():
    # the per-layer keep masks are the seeded state every engine replays;
    # a different fault seed must yield a different loss pattern, the
    # same seed the identical one
    p1 = _sim("compiled", FaultConfig(drop_p=0.15, seed=1)).drop_plan
    p2 = _sim("compiled", FaultConfig(drop_p=0.15, seed=2)).drop_plan
    p1b = _sim("compiled", FaultConfig(drop_p=0.15, seed=1)).drop_plan
    m1 = np.asarray(p1.mask(0, 0))
    assert not np.array_equal(m1, np.asarray(p2.mask(0, 0)))
    assert np.array_equal(m1, np.asarray(p1b.mask(0, 0)))
    # masks vary over timesteps too (per-t key folding)
    assert not np.array_equal(m1, np.asarray(p1.mask(0, 1)))


# ---------------------------------------------------------------------------
# zero-cost off: the hooks vanish from the lowered program


def _jaxpr(sim):
    x = np.zeros((2, 4, SIZES[0]), np.float32)
    s = str(jax.make_jaxpr(sim.array_engine().run_raw)(x))
    # custom_vjp params embed function reprs with raw memory addresses;
    # normalize those away so only structural differences remain
    return re.sub(r"0x[0-9a-f]+", "0x", s)


def test_null_faults_lower_to_identical_jaxpr():
    assert _jaxpr(_sim("compiled")) == _jaxpr(_sim("compiled", NULL_FAULTS))
    assert _jaxpr(_sim("compiled")) == _jaxpr(_sim("compiled", FaultConfig()))


def test_active_drop_plan_changes_the_jaxpr():
    assert (_jaxpr(_sim("compiled"))
            != _jaxpr(_sim("compiled", FaultConfig(drop_p=0.2, seed=3))))


# ---------------------------------------------------------------------------
# sampling + masking + codebook corruption


def test_sample_faults_deterministic_per_trial():
    kw = dict(routers=NOC.router_ids(), cores=NOC.core_ids(),
              router_kills=2, core_kills=1)
    assert sample_faults(5, **kw) == sample_faults(5, **kw)
    assert sample_faults(5, **kw) != sample_faults(5, trial=1, **kw)
    assert sample_faults(5, **kw) != sample_faults(6, **kw)


def test_masked_adjacency_removes_failed_routers_symmetrically():
    adj = NOC.fullerene_adjacency()
    f = FaultConfig(failed_routers=(3,), failed_links=((0, 1),))
    m = masked_adjacency(adj, f)
    assert m[3].sum() == 0 and m[:, 3].sum() == 0
    assert m[0, 1] == 0 and m[1, 0] == 0
    assert np.array_equal(m, m.T)
    # untouched rows keep their degree
    assert m[7].sum() == adj[7].sum() - adj[7, 3]


def test_fault_node_outside_graph_raises():
    with pytest.raises(ValueError, match="outside"):
        _sim("compiled", FaultConfig(dead_cores=(47,)))


def _quant_sim(faults=None):
    from repro.core.quant import CodebookConfig

    return ChipSimulator(_weights(), engine="compiled",
                         quant_cfg=CodebookConfig(n_levels=8, bit_width=8),
                         faults=faults)


def test_codebook_fault_changes_tables_deterministically():
    f = FaultConfig(codebook_faults=(
        CodebookFault(core_id=12, word=0, kind="stuck", value=3),))
    t1 = _quant_sim(f).register_tables
    t2 = _quant_sim(f).register_tables
    clean = _quant_sim().register_tables
    changed = any(not np.array_equal(np.asarray(a.codebook()),
                                     np.asarray(b.codebook()))
                  for a, b in zip(t1, clean))
    same = all(np.array_equal(np.asarray(a.codebook()),
                              np.asarray(b.codebook()))
               for a, b in zip(t1, t2))
    assert changed and same


def test_codebook_fault_on_unquantized_sim_fails_loudly():
    f = FaultConfig(codebook_faults=(
        CodebookFault(core_id=12, word=0, kind="stuck", value=3),))
    with pytest.raises(ValueError, match="quantized"):
        _sim("compiled", f)


def test_transient_dispatch_fault_raises_then_clears():
    sim = _sim("compiled", FaultConfig(transient_dispatches=(0,)))
    trains = _trains(batch=2, T=4)
    with pytest.raises(TransientChipFault):
        sim.run_batch(trains)
    counts, _ = sim.run_batch(trains)      # dispatch 1: healthy again
    clean, _ = _sim("compiled").run_batch(trains)
    assert np.array_equal(np.asarray(counts), np.asarray(clean))


# ---------------------------------------------------------------------------
# fault-aware repair


def _board():
    sizes = [64] + [96] * 8 + [16]
    spec = COMP.ChipSpec(neurons_per_core=8, max_domains=8)
    return from_layer_sizes(sizes), spec


def test_repair_router_kill_reuses_all_placements():
    net, spec = _board()
    kw = dict(seed=0, anneal_iters=800)
    prev = COMP.compile_network(net, spec, **kw)
    faults = FaultConfig(failed_routers=(3,))
    rep = COMP.repair(net, prev, faults, **kw)
    # a router kill changes no domain membership, so every cached
    # per-domain placement is reused — the repair is pure re-route
    assert rep.recompile_stats["reused"] == rep.recompile_stats["domains"]
    assert rep.faults is not None and rep.faults.rerouted
    routed = {int(n) for fl in rep.routed.layer_flows.values()
              for f in fl for uv in f.links for n in uv}
    assert 3 not in routed
    # and matches a from-scratch faulty compile bit for bit
    fresh = COMP.compile_network(net, spec,
                                 faults=faults.with_rerouted(), **kw)
    assert rep.placement.assignment == fresh.placement.assignment
    assert rep.cost == fresh.cost


def test_repaired_network_runs_end_to_end():
    net, spec = _board()
    kw = dict(seed=0, anneal_iters=800)
    prev = COMP.compile_network(net, spec, **kw)
    rep = COMP.repair(net, prev, FaultConfig(failed_routers=(3,)), **kw)
    sizes = [64] + [96] * 8 + [16]
    sim = ChipSimulator(_weights(sizes), engine="compiled",
                        mapping=rep.to_soc_mapping(), faults=rep.faults)
    counts, _ = sim.run_batch(_trains(sizes, batch=2, T=4))
    assert np.asarray(counts).shape == (2, 16)


def test_repair_dead_core_remaps_onto_spare_capacity():
    net, spec = _board()
    kw = dict(seed=0, anneal_iters=800, spread=False)
    prev = COMP.compile_network(net, spec, **kw)
    used = sorted({int(c) for c in prev.placement.assignment.values()})
    dead = used[0]
    rep = COMP.repair(net, prev, FaultConfig(dead_cores=(dead,)), **kw)
    assert dead not in set(rep.placement.assignment.values())
    assert len(set(rep.placement.assignment.values())) == len(used)


def test_repair_without_spare_capacity_fails_loudly():
    sizes = [64] + [96] * 8 + [16]
    net = from_layer_sizes(sizes)
    spec = COMP.ChipSpec(neurons_per_core=8, max_domains=8)
    kw = dict(seed=0, anneal_iters=800)
    prev = COMP.compile_network(net, spec, **kw)   # spread fills every core
    used = sorted({int(c) for c in prev.placement.assignment.values()})
    with pytest.raises(ValueError):
        COMP.repair(net, prev, FaultConfig(dead_cores=(used[0],)), **kw)


def test_disconnecting_fault_set_raises_value_error():
    sizes = [48, 64, 16]
    net = from_layer_sizes(sizes)
    prev = COMP.compile_network(net, seed=0, anneal_iters=400)
    # kill every level-1 router: nothing can route
    faults = FaultConfig(failed_routers=tuple(NOC.router_ids()))
    with pytest.raises(ValueError):
        COMP.repair(net, prev, faults, seed=0, anneal_iters=400)


# ---------------------------------------------------------------------------
# survivability


def test_survivability_study_fullerene_beats_mesh():
    s = survivability_study(k=4, trials=8, seed=0)
    assert s["routable_ratio_vs_mesh"] > 1.0
    assert s["saturation_ratio_vs_mesh"] > 1.0
    assert 0.0 < s["fullerene"]["routable_frac"] <= 1.0
    assert 0.0 < s["mesh"]["routable_frac"] <= 1.0


def test_survivability_study_is_seeded():
    a = survivability_study(k=2, trials=4, seed=3)
    b = survivability_study(k=2, trials=4, seed=3)
    assert a == b
