"""Serving-tier contract tests: admission validation, deadline/shed
semantics, transactional dispatch, multi-tenant isolation, and
continuous-batching liveness.

These pin the PR-7 serve semantics:
* submit validates T >= 1 and binary events (regression: pre-PR code
  accepted T=0 trains that crashed inside the engine scan);
* a failed engine launch leaves the server state untouched (regression:
  pre-PR `run` kept stale `t_dequeue` stamps and pre-recorded metrics);
* deadlines expire *before* launch, bounded queues shed explicitly;
* tenants on disjoint core sets are bit-identical to single-tenant
  serving, and residency swaps are priced as register-table DMAs.
"""
import numpy as np
import pytest

from repro.core import noc as NOC
from repro.core.soc import (ChipSimulator, HostDmaModel,
                            register_table_bytes, remap_mapping_cores)
from repro.serve import (DEADLINE_EXCEEDED, QUEUED, SERVED, SHED,
                         SnnRequest, SnnServer)
from repro.serve.admission import form_group, validate_events


class FakeClock:
    """Injectable monotonic clock for deterministic deadline tests."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def __call__(self) -> float:
        return self.t


def _net(seed=0, n_in=8, n_hidden=16, n_out=4):
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 0.5, (n_in, n_hidden)).astype(np.float32),
            rng.normal(0, 0.5, (n_hidden, n_out)).astype(np.float32)]


def _events(rng, T=6, n_in=8, p=0.3):
    return (rng.random((T, n_in)) < p).astype(np.float32)


# ---------------------------------------------------------------------------
# satellite S1: submit-time validation


def test_submit_rejects_zero_timestep_train():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"), batch_slots=2)
    with pytest.raises(ValueError, match="T >= 1"):
        srv.submit(SnnRequest(uid=0, events=np.zeros((0, 8), np.float32)))
    assert srv.queue == []


def test_submit_rejects_non_binary_events():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"), batch_slots=2)
    ev = np.zeros((4, 8), np.float32)
    ev[1, 3] = 0.7
    with pytest.raises(ValueError, match="binary"):
        srv.submit(SnnRequest(uid=0, events=ev))
    assert srv.queue == []


def test_submit_rejects_wrong_width_and_unknown_model():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"), batch_slots=2)
    with pytest.raises(ValueError, match=r"\(T, 8\)"):
        srv.submit(SnnRequest(uid=0, events=np.zeros((4, 9), np.float32)))
    with pytest.raises(ValueError, match="unknown model"):
        srv.submit(SnnRequest(uid=1, events=np.zeros((4, 8), np.float32),
                              model="nope"))


def test_validate_events_casts_to_f32_binary():
    ev = validate_events(np.ones((3, 8), np.int64), 8, uid=7)
    assert ev.dtype == np.float32 and ev.shape == (3, 8)


# ---------------------------------------------------------------------------
# satellite S2: transactional dispatch under engine faults


def test_engine_fault_leaves_server_state_untouched():
    clock = FakeClock()
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"),
                    batch_slots=4, clock=clock)
    rng = np.random.default_rng(1)
    reqs = [srv.submit(SnnRequest(uid=i, events=_events(rng)))
            for i in range(3)]

    real_run_batch = srv.sim.run_batch

    def boom(batch):
        raise RuntimeError("injected engine fault")

    srv.tenants["default"].sim.run_batch = boom
    with pytest.raises(RuntimeError, match="injected engine fault"):
        srv.step()

    # transactional: nothing served, no stale stamps, gauge exact,
    # no metrics recorded for the failed group
    assert [r.status for r in reqs] == [QUEUED] * 3
    assert all(r.t_dequeue is None for r in reqs)
    assert len(srv.queue) == 3
    assert srv.metrics.get("snn_queue_depth").value == 3
    assert srv.metrics.get("snn_batch_occupancy").count == 0
    assert srv.metrics.get("snn_requests_served_total").value == 0

    # recovery: restore the engine and the same queue drains cleanly
    srv.tenants["default"].sim.run_batch = real_run_batch
    done = srv.run()
    assert [r.status for r in done] == [SERVED] * 3
    assert srv.metrics.get("snn_batch_occupancy").count == 1


# ---------------------------------------------------------------------------
# deadline / shed semantics


def test_expired_request_completes_without_engine_launch():
    clock = FakeClock()
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"),
                    batch_slots=4, clock=clock)
    rng = np.random.default_rng(2)
    r = srv.submit(SnnRequest(uid=0, events=_events(rng), deadline_ms=10.0))
    assert r.status == QUEUED and r.deadline == pytest.approx(0.010)

    clock.advance(0.050)                       # blow the deadline
    srv.tenants["default"].sim.run_batch = lambda b: (_ for _ in ()).throw(
        AssertionError("expired request must not reach the engine"))
    done = srv.step()

    assert [x.status for x in done] == [DEADLINE_EXCEEDED]
    assert r.prediction is None and r.t_complete == clock.t
    assert srv.queue == []
    assert srv.metrics.get("snn_queue_depth").value == 0
    assert srv.metrics.get("snn_requests_deadline_exceeded_total").value == 1


def test_bounded_queue_sheds_explicitly_with_exact_gauge():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"),
                    batch_slots=2, max_queue_depth=2, clock=FakeClock())
    rng = np.random.default_rng(3)
    a = srv.submit(SnnRequest(uid=0, events=_events(rng)))
    b = srv.submit(SnnRequest(uid=1, events=_events(rng)))
    c = srv.submit(SnnRequest(uid=2, events=_events(rng)))

    assert a.status == QUEUED and b.status == QUEUED
    assert c.status == SHED and c.t_complete is not None
    assert len(srv.queue) == 2                  # shed never entered the queue
    assert srv.metrics.get("snn_queue_depth").value == 2
    assert srv.metrics.get("snn_requests_shed_total").value == 1
    assert srv.metrics.get(
        "snn_requests_shed_total", {"tenant": "default"}).value == 1

    done = srv.run()                            # shed request never served
    assert {r.uid for r in done} == {0, 1}


def test_group_formation_is_oldest_deadline_first():
    clock = FakeClock()
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"),
                    batch_slots=2, clock=clock)
    rng = np.random.default_rng(4)
    loose = srv.submit(SnnRequest(uid=0, events=_events(rng),
                                  deadline_ms=500.0))
    clock.advance(0.001)
    tight = srv.submit(SnnRequest(uid=1, events=_events(rng),
                                  deadline_ms=50.0))
    clock.advance(0.001)
    nodl = srv.submit(SnnRequest(uid=2, events=_events(rng)))

    group = form_group(srv.queue, slots=2, now=clock.t)
    assert [r.uid for r in group] == [1, 0]     # tight deadline leads
    assert nodl.uid not in [r.uid for r in group]


# ---------------------------------------------------------------------------
# continuous-batching liveness


def test_late_request_joins_next_group_not_full_drain():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"),
                    batch_slots=4, clock=FakeClock())
    rng = np.random.default_rng(5)
    for i in range(6):
        srv.submit(SnnRequest(uid=i, events=_events(rng)))

    first = srv.step()                          # one slot group, not a drain
    assert len(first) == 4 and len(srv.queue) == 2

    late = srv.submit(SnnRequest(uid=99, events=_events(rng)))
    second = srv.step()
    assert late in second                       # joined the very next group
    assert {r.uid for r in second} == {4, 5, 99}
    assert late.t_dequeue == second[0].t_dequeue
    assert srv.queue == []


# ---------------------------------------------------------------------------
# multi-model tenancy


def test_multi_tenant_disjoint_cores_bit_identical_to_single_tenant():
    wa, wb = _net(seed=10), _net(seed=11, n_in=8, n_hidden=12, n_out=4)
    # greedy packs contiguously (minimal cores), leaving room for tenant b
    sim_a = ChipSimulator(wa, engine="compiled", mapping_strategy="greedy")
    base_b = ChipSimulator(wb, engine="compiled", mapping_strategy="greedy")
    used_a = set(sim_a.mapping.active_core_ids())
    pool = [int(c) for c in NOC.core_ids() if int(c) not in used_a]
    mapping_b = remap_mapping_cores(
        base_b.mapping, pool[-len(base_b.mapping.active_core_ids()):])
    sim_b = ChipSimulator(wb, engine="compiled", mapping=mapping_b)

    rng = np.random.default_rng(6)
    trains = [_events(rng) for _ in range(6)]

    multi = SnnServer(sim_a, batch_slots=4, clock=FakeClock())
    tb = multi.add_model("b", sim_b)
    assert not (multi.tenants["default"].core_ids & tb.core_ids)
    for i, ev in enumerate(trains):
        multi.submit(SnnRequest(uid=i, events=ev,
                                model="b" if i % 2 else "default"))
    served = {r.uid: r for r in multi.run()}

    solo_a = SnnServer(ChipSimulator(wa, engine="compiled", mapping=sim_a.mapping),
                       batch_slots=4, clock=FakeClock())
    solo_b = SnnServer(ChipSimulator(wb, engine="compiled", mapping=mapping_b),
                       batch_slots=4, clock=FakeClock())
    for i, ev in enumerate(trains):
        (solo_b if i % 2 else solo_a).submit(SnnRequest(uid=i, events=ev))
    solo = {r.uid: r for r in solo_a.run() + solo_b.run()}

    for uid in served:
        assert served[uid].prediction == solo[uid].prediction
        np.testing.assert_array_equal(served[uid].spike_counts,
                                      solo[uid].spike_counts)

    # disjoint tenants co-reside: each loaded once, never evicted
    hs = multi.host_summary()
    assert hs["model_swaps"] == 2 and hs["swap_pj"] > 0


def test_overlapping_tenants_swap_and_cost_is_register_table_dma():
    wa, wb = _net(seed=20), _net(seed=21)
    sim_a = ChipSimulator(wa, engine="compiled")
    # same default mapping strategy -> overlapping core sets
    sim_b = ChipSimulator(wb, engine="compiled", mapping=sim_a.mapping)
    dma = HostDmaModel()
    srv = SnnServer(sim_a, batch_slots=2, dma=dma, clock=FakeClock())
    srv.add_model("b", sim_b)
    assert srv.tenants["default"].core_ids & srv.tenants["b"].core_ids

    rng = np.random.default_rng(7)
    # a, b, a: serving order forces default -> b -> default reloads
    for i, model in enumerate(["default", "b", "default"]):
        srv.submit(SnnRequest(uid=i, events=_events(rng), model=model))
        srv.step()

    hs = srv.host_summary()
    assert hs["model_swaps"] == 3
    pj_a, _ = dma.table_load(sim_a.register_tables)
    pj_b, _ = dma.table_load(sim_b.register_tables)
    assert hs["swap_pj"] == pytest.approx(2 * pj_a + pj_b)
    assert srv.metrics.get("snn_model_swap_pj_total",
                           {"tenant": "b"}).value == pytest.approx(pj_b)


def test_served_requests_carry_dma_cost_separate_from_chip_energy():
    sim = ChipSimulator(_net(), engine="compiled")
    srv = SnnServer(sim, batch_slots=2, clock=FakeClock())
    rng = np.random.default_rng(8)
    r = srv.submit(SnnRequest(uid=0, events=_events(rng)))
    srv.run()

    up_pj, up_cyc = srv.dma.spike_upload(r.timesteps, 8)
    out_pj, _ = srv.dma.output_read(4)
    assert r.dma_pj == pytest.approx(up_pj + out_pj)
    assert up_pj > 0 and up_cyc > 0
    # chip-model energy stays the engines' accounting, DMA is additive
    counts, reports = sim.run_batch(
        np.stack([r.events, np.zeros_like(r.events)])[:, :, :])
    assert r.energy_pj == pytest.approx(reports[0].energy_pj, rel=1e-12)


def test_host_dma_model_packetization():
    dma = HostDmaModel(word_bits=32, words_per_packet=4, header_words=1,
                       setup_cycles=10.0, cycles_per_word=2.0,
                       pj_per_word=1.0)
    assert dma.transfer(0) == (0.0, 0.0)
    pj, cyc = dma.transfer(5)                   # 2 packets, 5+2 wire words
    assert pj == pytest.approx(7.0)
    assert cyc == pytest.approx(10.0 + 2.0 * 7)
    # spike upload bitpacks 16 axon bits per chip halfword, 2 per DMA word
    pj1, _ = dma.spike_upload(timesteps=4, n_in=16)
    pj2, _ = dma.spike_upload(timesteps=4, n_in=64)
    assert pj2 > pj1
    assert register_table_bytes(
        ChipSimulator(_net(), engine="compiled").register_tables[0]) > 0


# ---------------------------------------------------------------------------
# PR 9: dispatch resilience — retry, timeout, circuit breaking, degraded


from repro.faults import FaultConfig, TransientChipFault  # noqa: E402
from repro.serve.resilience import (CircuitOpenError,  # noqa: E402
                                    DispatchTimeout, RetryPolicy)


def _faulty_sim(*dispatches):
    return ChipSimulator(_net(), engine="compiled",
                         faults=FaultConfig(
                             transient_dispatches=tuple(dispatches)))


def test_retry_recovers_from_injected_transient_fault():
    srv = SnnServer(_faulty_sim(0), batch_slots=4,
                    retry=RetryPolicy(max_retries=2, base_delay_s=0.0))
    rng = np.random.default_rng(3)
    r = srv.submit(SnnRequest(uid=0, events=_events(rng)))
    done = srv.run()
    assert done[0].status == SERVED and not done[0].degraded
    assert srv._m_faults.value == 1
    assert srv._m_retries.value == 1
    assert srv._m_degraded.value == 0


def test_mid_scan_chip_fault_is_transactional_when_retries_off():
    """Satellite: a transient fault from the fault model (the scan ran,
    the readback was lost) with retries disabled must take the exact
    PR-7 transactional unwind — queue, stamps, and metrics untouched."""
    srv = SnnServer(_faulty_sim(0), batch_slots=4,
                    retry=RetryPolicy(max_retries=0))
    rng = np.random.default_rng(4)
    reqs = [srv.submit(SnnRequest(uid=i, events=_events(rng)))
            for i in range(3)]
    with pytest.raises(TransientChipFault):
        srv.step()
    assert [r.status for r in reqs] == [QUEUED] * 3
    assert all(r.t_dequeue is None for r in reqs)
    assert len(srv.queue) == 3
    assert srv.metrics.get("snn_queue_depth").value == 3
    assert srv.metrics.get("snn_requests_served_total").value == 0
    assert srv._m_faults.value == 1 and srv._m_retries.value == 0
    # the faulty dispatch is consumed: the same queue then drains
    done = srv.run()
    assert [r.status for r in done] == [SERVED] * 3


def test_degraded_fallback_after_retry_exhaustion():
    srv = SnnServer(None, batch_slots=4,
                    retry=RetryPolicy(max_retries=1, base_delay_s=0.0),
                    sleep=lambda s: None)
    srv.add_model("default", _faulty_sim(0, 1, 2, 3),
                  degraded_sim=ChipSimulator(_net(), engine="compiled"))
    rng = np.random.default_rng(5)
    srv.submit(SnnRequest(uid=0, events=_events(rng)))
    done = srv.run()
    assert done[0].status == SERVED and done[0].degraded
    assert srv._m_degraded.value == 1
    assert srv._m_faults.value == 2      # initial try + 1 retry, both lost


def test_dispatch_timeout_is_classified_transient():
    class AdvancingClock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 10.0
            return self.t

    srv = SnnServer(ChipSimulator(_net(), engine="compiled"), batch_slots=4,
                    clock=AdvancingClock(), retry=RetryPolicy(max_retries=0),
                    dispatch_timeout_s=1.0)
    rng = np.random.default_rng(6)
    r = srv.submit(SnnRequest(uid=0, events=_events(rng)))
    with pytest.raises(DispatchTimeout):
        srv.step()
    assert r.status == QUEUED and srv._m_faults.value == 1


def test_circuit_breaker_opens_serves_degraded_then_recovers():
    clock = FakeClock()
    faulty = _faulty_sim(0)
    srv = SnnServer(None, batch_slots=4, clock=clock,
                    retry=RetryPolicy(max_retries=0, base_delay_s=0.0),
                    breaker_threshold=1, breaker_cooldown_s=5.0,
                    sleep=lambda s: None)
    srv.add_model("default", faulty,
                  degraded_sim=ChipSimulator(_net(), engine="compiled"))
    rng = np.random.default_rng(7)

    srv.submit(SnnRequest(uid=0, events=_events(rng)))
    done = srv.run()
    assert done[0].degraded and srv.breakers["default"].state == "open"
    # while open the primary is never dispatched
    dispatches = faulty._dispatch_count
    srv.submit(SnnRequest(uid=1, events=_events(rng)))
    done = srv.run()
    assert done[0].degraded and faulty._dispatch_count == dispatches
    # cooldown elapses -> half_open trial succeeds -> closed again
    clock.advance(10.0)
    srv.submit(SnnRequest(uid=2, events=_events(rng)))
    done = srv.run()
    assert not done[0].degraded
    assert srv.breakers["default"].state == "closed"


def test_open_circuit_without_degraded_model_keeps_queue():
    clock = FakeClock()
    srv = SnnServer(None, batch_slots=4, clock=clock,
                    retry=RetryPolicy(max_retries=0, base_delay_s=0.0),
                    breaker_threshold=1, breaker_cooldown_s=5.0)
    srv.add_model("default", _faulty_sim(0))
    rng = np.random.default_rng(8)
    r = srv.submit(SnnRequest(uid=0, events=_events(rng)))
    with pytest.raises(TransientChipFault):
        srv.step()
    with pytest.raises(CircuitOpenError):
        srv.step()
    assert r.status == QUEUED and len(srv.queue) == 1
    assert r.t_dequeue is None


def test_nonretryable_error_is_never_retried():
    srv = SnnServer(ChipSimulator(_net(), engine="compiled"), batch_slots=4,
                    retry=RetryPolicy(max_retries=3, base_delay_s=0.0))
    calls = []

    def boom(batch):
        calls.append(1)
        raise RuntimeError("real bug")

    srv.tenants["default"].sim.run_batch = boom
    rng = np.random.default_rng(9)
    srv.submit(SnnRequest(uid=0, events=_events(rng)))
    with pytest.raises(RuntimeError, match="real bug"):
        srv.step()
    assert len(calls) == 1 and srv._m_retries.value == 0
