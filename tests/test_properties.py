"""Cross-cutting property tests (hypothesis) on the system's invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import energy as E
from repro.core.zspe import CoreGeometry, CycleModel
from repro.data.synthetic import EventStream


# ---------------------------------------------------------------------------
# energy / cycle model invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(s1=st.floats(0.0, 1.0), s2=st.floats(0.0, 1.0))
def test_energy_monotone_in_sparsity(s1, s2):
    """More sparsity never costs more energy or throughput (zero-skip)."""
    core = E.calibrate_core()
    lo, hi = min(s1, s2), max(s1, s2)
    assert core.pj_per_sop(hi) <= core.pj_per_sop(lo) + 1e-12
    assert core.gsops(hi) >= core.gsops(lo) - 1e-12


@settings(max_examples=30, deadline=None)
@given(s=st.floats(0.0, 1.0))
def test_zero_skip_never_loses(s):
    core = E.calibrate_core()
    assert core.pj_per_sop(s, zero_skip=True) <= \
        core.pj_per_sop(s, zero_skip=False) + 1e-12
    assert core.pj_per_sop(s, partial_update=True) <= \
        core.pj_per_sop(s, partial_update=False) + 1e-12


def test_stage_cycles_are_integer_counts():
    """The docstring's contract: ceil(nnz * n_post / 4) synapse cycles
    and integer update cycles, in BOTH the scalar and the array path
    (they must agree exactly — the engines' 1e-6 differential contract
    rides on it)."""
    cm = CycleModel(CoreGeometry())
    load, syn, upd = cm.stage_cycles(100, 7, nnz=3.0, touched=2.5)
    assert load == -(-100 // 16)
    assert syn == -(-3 * 7 // 4) == 6          # ceil(21/4), not 5.25
    assert upd == 3                            # ceil(2.5)
    l2, s2, u2 = cm.stage_cycles_array(
        100, jnp.asarray([7.0]), jnp.asarray(3.0), jnp.asarray([2.5]))
    assert (int(l2), float(s2[0]), float(u2[0])) == (load, syn, upd)
    # baseline scheme: every synapse, every neuron
    _, syn_b, upd_b = cm.stage_cycles(100, 7, 3.0, 2.5,
                                      zero_skip=False, partial_update=False)
    assert syn_b == -(-100 * 7 // 4) and upd_b == 7
    _, s2b, u2b = cm.stage_cycles_array(
        100, jnp.asarray([7.0]), jnp.asarray(3.0), jnp.asarray([2.5]),
        zero_skip=False, partial_update=False)
    assert (float(s2b[0]), float(u2b[0])) == (syn_b, upd_b)


@settings(max_examples=20, deadline=None)
@given(
    n_pre=st.integers(16, 4096),
    n_post=st.integers(1, 8192),
    s=st.floats(0.0, 1.0),
)
def test_cycle_model_bounds(n_pre, n_post, s):
    """Zero-skip cycles <= baseline cycles; SOPs scale with density."""
    cm = CycleModel(CoreGeometry())
    nnz = n_pre * (1.0 - s)
    touched = min(nnz, n_post)       # touched neurons cannot exceed the core
    opt = cm.timestep_cycles(n_pre, n_post, nnz, touched, True, True)
    base = cm.timestep_cycles(n_pre, n_post, nnz, n_post, False, False)
    assert opt <= base + 1e-9
    assert cm.sop_count(n_pre, n_post, nnz, True) <= \
        cm.sop_count(n_pre, n_post, nnz, False) + 1e-9


def test_chip_model_chip_never_beats_core():
    """System overhead is non-negative at every sparsity."""
    chip = E.calibrate_chip()
    for s in np.linspace(0, 1, 11):
        assert chip.chip_pj_per_sop(float(s)) >= chip.core.pj_per_sop(float(s))


# ---------------------------------------------------------------------------
# SNN QAT ablation (paper's offline-training story)
# ---------------------------------------------------------------------------

def test_snn_qat_matches_ptq_or_better():
    """Training WITH fake-quant (STE) should be at least as robust to the
    chip's 16x8 codebook as post-training quantization."""
    from repro.models import snn as SNN

    ev = EventStream(timesteps=6, height=10, width=10, seed=3)
    base = SNN.SNNConfig(layer_sizes=(ev.n_inputs, 96, 10), timesteps=6)
    qat = dataclasses.replace(base, qat=True)

    def train(cfg):
        params = SNN.init_params(cfg, jax.random.PRNGKey(1))
        for step in range(40):
            sp, lb = ev.batch(64, step)
            params, _, _ = SNN.sgd_step(params, cfg, sp, lb, lr=0.3)
        return params

    sp, lb = ev.batch(128, 7777)
    p_fp = train(base)
    acc_ptq = float(SNN.accuracy(
        SNN.dequantized(SNN.quantize_for_chip(p_fp, base)), base, sp, lb))
    p_qat = train(qat)
    acc_qat = float(SNN.accuracy(
        SNN.dequantized(SNN.quantize_for_chip(p_qat, qat)), base, sp, lb))
    assert acc_qat >= acc_ptq - 0.08, (acc_qat, acc_ptq)
    assert acc_qat > 0.75


# ---------------------------------------------------------------------------
# event data invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_event_stream_sparsity_regime(seed):
    """Synthetic event data stays in the chip's sparse operating regime."""
    ev = EventStream(timesteps=6, height=12, width=12, seed=seed)
    s = ev.measured_sparsity(batch_size=8)
    assert 0.7 < s < 0.999


def test_event_stream_deterministic():
    ev = EventStream(timesteps=4, height=8, width=8, seed=5)
    a, la = ev.batch(4, step=9)
    b, lb = ev.batch(4, step=9)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# codebook quantization: chip-format invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), w=st.sampled_from([4, 8, 16]),
       scale=st.floats(1e-3, 10.0))
def test_quant_scale_equivariance(n, w, scale):
    """Quantizing c*W matches c*(quantized W): codebooks are per-tensor."""
    from repro.core.quant import CodebookConfig, dequantize, quantize

    key = jax.random.PRNGKey(n * 7 + w)
    wts = jax.random.normal(key, (32, 32))
    cfg = CodebookConfig(n_levels=n, bit_width=w)
    q1 = dequantize(quantize(wts * scale, cfg))
    q2 = dequantize(quantize(wts, cfg)) * scale
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=0.05, atol=0.05 * scale)
