"""Cross-cutting property tests (hypothesis) on the system's invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import energy as E
from repro.core.zspe import CoreGeometry, CycleModel
from repro.data.synthetic import EventStream


# ---------------------------------------------------------------------------
# energy / cycle model invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(s1=st.floats(0.0, 1.0), s2=st.floats(0.0, 1.0))
def test_energy_monotone_in_sparsity(s1, s2):
    """More sparsity never costs more energy or throughput (zero-skip)."""
    core = E.calibrate_core()
    lo, hi = min(s1, s2), max(s1, s2)
    assert core.pj_per_sop(hi) <= core.pj_per_sop(lo) + 1e-12
    assert core.gsops(hi) >= core.gsops(lo) - 1e-12


@settings(max_examples=30, deadline=None)
@given(s=st.floats(0.0, 1.0))
def test_zero_skip_never_loses(s):
    core = E.calibrate_core()
    assert core.pj_per_sop(s, zero_skip=True) <= \
        core.pj_per_sop(s, zero_skip=False) + 1e-12
    assert core.pj_per_sop(s, partial_update=True) <= \
        core.pj_per_sop(s, partial_update=False) + 1e-12


def test_stage_cycles_are_integer_counts():
    """The docstring's contract: ceil(nnz * n_post / 4) synapse cycles
    and integer update cycles, in BOTH the scalar and the array path
    (they must agree exactly — the engines' 1e-6 differential contract
    rides on it)."""
    cm = CycleModel(CoreGeometry())
    load, syn, upd = cm.stage_cycles(100, 7, nnz=3.0, touched=2.5)
    assert load == -(-100 // 16)
    assert syn == -(-3 * 7 // 4) == 6          # ceil(21/4), not 5.25
    assert upd == 3                            # ceil(2.5)
    l2, s2, u2 = cm.stage_cycles_array(
        100, jnp.asarray([7.0]), jnp.asarray(3.0), jnp.asarray([2.5]))
    assert (int(l2), float(s2[0]), float(u2[0])) == (load, syn, upd)
    # baseline scheme: every synapse, every neuron
    _, syn_b, upd_b = cm.stage_cycles(100, 7, 3.0, 2.5,
                                      zero_skip=False, partial_update=False)
    assert syn_b == -(-100 * 7 // 4) and upd_b == 7
    _, s2b, u2b = cm.stage_cycles_array(
        100, jnp.asarray([7.0]), jnp.asarray(3.0), jnp.asarray([2.5]),
        zero_skip=False, partial_update=False)
    assert (float(s2b[0]), float(u2b[0])) == (syn_b, upd_b)


@settings(max_examples=20, deadline=None)
@given(
    n_pre=st.integers(16, 4096),
    n_post=st.integers(1, 8192),
    s=st.floats(0.0, 1.0),
)
def test_cycle_model_bounds(n_pre, n_post, s):
    """Zero-skip cycles <= baseline cycles; SOPs scale with density."""
    cm = CycleModel(CoreGeometry())
    nnz = n_pre * (1.0 - s)
    touched = min(nnz, n_post)       # touched neurons cannot exceed the core
    opt = cm.timestep_cycles(n_pre, n_post, nnz, touched, True, True)
    base = cm.timestep_cycles(n_pre, n_post, nnz, n_post, False, False)
    assert opt <= base + 1e-9
    assert cm.sop_count(n_pre, n_post, nnz, True) <= \
        cm.sop_count(n_pre, n_post, nnz, False) + 1e-9


def test_chip_model_chip_never_beats_core():
    """System overhead is non-negative at every sparsity."""
    chip = E.calibrate_chip()
    for s in np.linspace(0, 1, 11):
        assert chip.chip_pj_per_sop(float(s)) >= chip.core.pj_per_sop(float(s))


# ---------------------------------------------------------------------------
# SNN QAT ablation (paper's offline-training story)
# ---------------------------------------------------------------------------

def test_snn_qat_matches_ptq_or_better():
    """Training WITH fake-quant (STE) should be at least as robust to the
    chip's 16x8 codebook as post-training quantization."""
    from repro.core.quant import dequantize, quantize
    from repro.models import snn as SNN
    from repro.train.snn_trainer import SNNTrainConfig, SNNTrainer

    ev = EventStream(timesteps=6, height=10, width=10, seed=3)
    base = SNN.SNNConfig(layer_sizes=(ev.n_inputs, 96, 10), timesteps=6)
    qat = dataclasses.replace(base, qat=True)

    def train(cfg):
        params, _ = SNNTrainer(
            cfg, SNNTrainConfig(steps=40, batch=64, lr=4e-3, log_every=0)
        ).fit(lambda step: ev.batch(64, step))
        return params

    def chip_acc(params, cfg):
        deq = [dequantize(quantize(w, cfg.quant)) for w in params]
        return float(SNN.accuracy(deq, base, sp, lb))

    sp, lb = ev.batch(128, 7777)
    acc_ptq = chip_acc(train(base), base)
    acc_qat = chip_acc(train(qat), qat)
    assert acc_qat >= acc_ptq - 0.08, (acc_qat, acc_ptq)
    assert acc_qat > 0.75


# ---------------------------------------------------------------------------
# codebook projection (the on-chip plasticity write constraint)
# ---------------------------------------------------------------------------
#
# `quant.project_to_codebook` is the only way a learning rule can touch a
# synapse (core/plasticity.py): float candidate -> nearest W-bit table
# level.  The engine differential contract rides on three properties,
# checked over every chip table geometry (N, W) in {4, 8, 16}^2:
# idempotence (a projected weight re-projects to the same index, even
# with duplicate table levels), exact fixed points on the levels
# themselves, and bit-exact scalar/batched agreement.


def _table_levels(rng, n: int, w: int, distinct: bool) -> np.ndarray:
    """A plausible chip table: N signed W-bit words x a fixed-point step."""
    lo, hi = -(2 ** (w - 1)), 2 ** (w - 1) - 1
    words = rng.choice(np.arange(lo, hi + 1), size=n, replace=not distinct)
    scale = np.float32(10.0 ** rng.uniform(-3, 1))
    return (words.astype(np.float32) * scale).astype(np.float32)


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from((4, 8, 16)), w=st.sampled_from((4, 8, 16)),
       seed=st.integers(0, 1000), distinct=st.booleans())
def test_project_to_codebook_idempotent(n, w, seed, distinct):
    """project(dequant(project(v))) == project(v) — duplicate levels
    included (first-occurrence tie-breaking makes re-projection stable,
    so dw == 0 can never be counted as a register write)."""
    from repro.core.quant import project_to_codebook

    rng = np.random.default_rng(seed)
    cb = _table_levels(rng, n, w, distinct)
    v = rng.normal(0, float(np.abs(cb).max() or 1.0), (5, 7)
                   ).astype(np.float32)
    idx = project_to_codebook(v, cb)
    assert idx.dtype == jnp.int8
    assert int(idx.min()) >= 0 and int(idx.max()) < n
    again = project_to_codebook(cb[np.asarray(idx)], cb)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(again))


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from((4, 8, 16)), w=st.sampled_from((4, 8, 16)),
       seed=st.integers(0, 1000))
def test_project_to_codebook_fixed_points(n, w, seed):
    """Every distinct table level is an exact fixed point: projecting the
    level vector itself returns 0..N-1 identically."""
    from repro.core.quant import project_to_codebook

    rng = np.random.default_rng(seed)
    cb = _table_levels(rng, n, w, distinct=True)
    idx = project_to_codebook(cb, cb)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(n))


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from((4, 8, 16)), w=st.sampled_from((4, 8, 16)),
       seed=st.integers(0, 1000))
def test_project_to_codebook_scalar_batched_agree(n, w, seed):
    """One batched projection == N scalar projections, bit-exact — the
    engines project whole (K, N) blocks in-scan while the reference
    oracle could project element-wise; they must never disagree."""
    from repro.core.quant import project_to_codebook

    rng = np.random.default_rng(seed)
    cb = _table_levels(rng, n, w, distinct=False)
    v = rng.normal(0, float(np.abs(cb).max() or 1.0), (3, 6)
                   ).astype(np.float32)
    batched = np.asarray(project_to_codebook(v, cb))
    scalar = np.array([[int(project_to_codebook(np.float32(x), cb))
                        for x in row] for row in v], batched.dtype)
    np.testing.assert_array_equal(batched, scalar)


def test_project_to_codebook_per_column_tables():
    """(N, cols) per-column form == column-wise 1-D projections (the
    layout the engines carry when core slices program different
    RegisterTables), and shape mismatches fail loudly."""
    from repro.core.quant import project_to_codebook

    rng = np.random.default_rng(9)
    cols = 5
    cb2 = np.stack([_table_levels(rng, 8, 8, True) for _ in range(cols)],
                   axis=1)                                 # (N, cols)
    v = rng.normal(0, 1, (4, cols)).astype(np.float32)
    got = np.asarray(project_to_codebook(v, cb2))
    want = np.stack([np.asarray(project_to_codebook(v[:, j], cb2[:, j]))
                     for j in range(cols)], axis=1)
    np.testing.assert_array_equal(got, want)
    with pytest.raises(ValueError, match="codebook"):
        project_to_codebook(v, cb2[:, :3])


# ---------------------------------------------------------------------------
# event data invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_event_stream_sparsity_regime(seed):
    """Synthetic event data stays in the chip's sparse operating regime."""
    ev = EventStream(timesteps=6, height=12, width=12, seed=seed)
    s = ev.measured_sparsity(batch_size=8)
    assert 0.7 < s < 0.999


def test_event_stream_deterministic():
    ev = EventStream(timesteps=4, height=8, width=8, seed=5)
    a, la = ev.batch(4, step=9)
    b, lb = ev.batch(4, step=9)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# codebook quantization: chip-format invariants
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), w=st.sampled_from([4, 8, 16]),
       scale=st.floats(1e-3, 10.0))
def test_quant_scale_equivariance(n, w, scale):
    """Quantizing c*W matches c*(quantized W): codebooks are per-tensor."""
    from repro.core.quant import CodebookConfig, dequantize, quantize

    key = jax.random.PRNGKey(n * 7 + w)
    wts = jax.random.normal(key, (32, 32))
    cfg = CodebookConfig(n_levels=n, bit_width=w)
    q1 = dequantize(quantize(wts * scale, cfg))
    q2 = dequantize(quantize(wts, cfg)) * scale
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=0.05, atol=0.05 * scale)
