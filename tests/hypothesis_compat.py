"""Optional-hypothesis shim for the test suite.

`hypothesis` powers the property sweeps but is not part of the runtime
image.  Importing through this module keeps collection working either
way: with hypothesis installed the real `given`/`settings`/`st` are
re-exported; without it, `@given(...)` marks the test skipped (with a
clear reason) and the rest of the suite still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert stand-in: strategy constructors become no-ops."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategy()
