"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs.
The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.models import transformer as T

KEY = jax.random.PRNGKey(7)


def smoke_batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (b, cfg.enc_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", R.ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = R.get_arch(arch, smoke=True)
    # smoke configs stay in f32 on CPU
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params, specs = T.init_model(cfg, KEY)
    batch = smoke_batch(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: T.forward_train(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree.leaves(grads):
        assert not bool(jnp.any(jnp.isnan(leaf))), arch

    # one SGD step moves the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2 = T.forward_train(params2, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", R.ARCH_NAMES)
def test_smoke_prefill_decode(arch):
    cfg = R.get_arch(arch, smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params, _ = T.init_model(cfg, KEY)
    batch = smoke_batch(cfg, b=2, s=8)
    logits, state = T.forward_prefill(params, cfg, batch, cache_len=32)
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    lg, state = T.forward_decode(params, cfg, state, batch["tokens"][:, :1])
    assert lg.shape == (2, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    a = R.get_arch("moonshot-v1-16b-a3b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (48, 2048, 16, 16)
    assert (a.d_ff, a.vocab, a.n_experts, a.top_k) == (1408, 163840, 64, 6)
    a = R.get_arch("granite-moe-1b-a400m")
    assert (a.n_layers, a.d_model, a.n_experts, a.top_k) == (24, 1024, 32, 8)
    a = R.get_arch("zamba2-2.7b")
    assert (a.n_layers, a.d_model, a.ssm_state) == (54, 2560, 64)
    a = R.get_arch("granite-3-8b")
    assert (a.n_layers, a.d_model, a.d_ff) == (40, 4096, 12800)
    a = R.get_arch("mistral-large-123b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (88, 12288, 96, 8)
    a = R.get_arch("yi-9b")
    assert (a.n_layers, a.d_model, a.n_kv_heads, a.vocab) == (48, 4096, 4, 64000)
    a = R.get_arch("granite-3-2b")
    assert (a.n_layers, a.d_model, a.d_ff) == (40, 2048, 8192)
    a = R.get_arch("mamba2-130m")
    assert (a.n_layers, a.d_model, a.ssm_state) == (24, 768, 128)
    a = R.get_arch("whisper-tiny")
    assert (a.n_layers, a.d_model, a.n_heads, a.vocab) == (4, 384, 6, 51865)
    a = R.get_arch("phi-3-vision-4.2b")
    assert (a.n_layers, a.d_model, a.d_ff, a.vocab) == (32, 3072, 8192, 32064)


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic archs (DESIGN.md)."""
    from repro.models.common import SHAPES
    runnable = {a: R.cell_is_runnable(R.get_arch(a), SHAPES["long_500k"])[0]
                for a in R.ARCH_NAMES}
    assert runnable == {
        "moonshot-v1-16b-a3b": False, "granite-moe-1b-a400m": False,
        "zamba2-2.7b": True, "granite-3-8b": False,
        "mistral-large-123b": False, "yi-9b": False, "granite-3-2b": False,
        "mamba2-130m": True, "whisper-tiny": False, "phi-3-vision-4.2b": False,
    }
